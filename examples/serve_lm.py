"""Serving example: continuous-batching engine over a reduced model.

Admits a queue of prompt requests into fixed decode slots, prefills each
(splicing its KV cache into the batch cache), then decodes all active
slots in lock-step — the serving pattern the decode dry-run cells lower
at production shape.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "llama3.2-3b", "--reduced",
                "--requests", "6", "--slots", "3", "--prompt-len", "12",
                "--max-new", "12", "--max-seq", "64"] + sys.argv[1:]
    main()
