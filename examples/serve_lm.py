"""Serving example: continuous-batching engine over a reduced model.

Admits a queue of prompt requests into fixed decode slots, prefills each
at its bucketed length (paged KV cache when the config supports it),
then decodes all active slots together — each at its own position.
Prefill and decode run on *separate* FTL plans (the memory-bound m=1
decode DP generally picks different cuts), both AOT-warmed so steady
state never replans.

Run:  PYTHONPATH=src python examples/serve_lm.py

Extra flags pass straight through to ``repro.launch.serve``:

  --arrival-rate 8         open-loop Poisson arrivals at 8 req/s
                           (default: everything arrives at t=0)
  --trace decode.json      Chrome-tracing timeline of the decode plan's
                           simulated schedule (load in Perfetto or
                           chrome://tracing); with --obs it becomes the
                           merged live+modeled timeline, written post-run
  --obs                    runtime telemetry: lifecycle spans, queue/KV
                           gauges, the online drift monitor
  --obs-trace live.json    merged live+modeled Perfetto timeline
                           (implies --obs; must differ from --trace —
                           the same path is rejected, not overwritten)
  --obs-metrics serve.prom Prometheus text exposition of the metrics
                           registry (implies --obs)
  --target rv32_npu        plan for a specific memory-hierarchy preset
  --block-size 16          paged-KV page length; --dense-kv disables
                           paging
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "llama3.2-3b", "--reduced",
                "--requests", "6", "--slots", "3", "--prompt-len", "12",
                "--max-new", "12", "--max-seq", "64"] + sys.argv[1:]
    main()
