"""Quickstart: the FTL pipeline end to end on the paper's benchmark.

1. build the fusion group (paper steps 1+3),
2. solve the joint tiling problem (steps 2+4),
3. compare fused vs layer-per-layer traffic (the paper's headline),
4. execute the fused plan with the Pallas kernel (interpret mode on CPU)
   and check it against the jnp oracle.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ftl, hw
from repro.kernels import ref
from repro.kernels.gemm_gelu import gemm_act

MB = 1 << 20


def main() -> None:
    # --- the paper's benchmark op: H = GeLU(X @ W1) ----------------------
    m, k, n = 3072, 768, 3072
    target = hw.TPU_V5E
    print(f"ViT-MLP GEMM+GeLU: X({m}x{k}) @ W1({k}x{n}) "
          f"on {target.describe()}\n")

    fused = ftl.solve(ftl.fusion.gemm_act(m=m, k=k, n=n, fuse=True),
                      target=target)
    unfused = [ftl.solve(g, target=target)
               for g in ftl.fusion.gemm_act(m=m, k=k, n=n, fuse=False)]

    print(fused.summary())
    print()
    cmp = ftl.compare(fused, unfused)
    print("fused vs layer-per-layer:", cmp.summary())
    print()

    # --- run the fused kernel the plan drives ----------------------------
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, k), jnp.float32) * 0.1
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32) * 0.05
    bm, bn = fused.tile("M"), fused.tile("F")
    bk = fused.tile("K")
    y = gemm_act(x, w, act="gelu", block_m=bm, block_n=bn, block_k=bk,
                 interpret=jax.default_backend() != "tpu")
    y_ref = ref.gemm_act(x, w, act="gelu")
    err = float(jnp.abs(y - y_ref).max())
    print(f"pallas fused kernel vs oracle: max err {err:.2e}")
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
    print("OK")


if __name__ == "__main__":
    main()
